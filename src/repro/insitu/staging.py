"""Device→host staging area for in-transit analysis (paper fig. 1).

Models Hercule's staging nodes: the compute flow hands a snapshot to the
staging area and immediately continues; the analysis flow drains it at
its own pace. Three pieces:

  * **double-buffered host buffers** — a small pool of reusable host-side
    buffer sets. The push copies device (or live host) arrays into a free
    buffer set, so compute may mutate its arrays right after ``push``
    returns and steady-state pushes reuse memory instead of allocating
    (classic double buffering: one set being filled while others are in
    flight through the queue/workers).
  * **bounded queue** — at most ``capacity`` staged snapshots wait for the
    engine; in-flight snapshots (popped, being reduced) hold their buffer
    set until :meth:`release`.
  * **explicit backpressure policy** when the queue (or buffer pool) is
    full:
      - ``block``       compute waits for space (lossless, may stall);
      - ``drop-oldest`` evict the oldest waiting snapshot, accept the new
        one (viewers always see the freshest data; compute never stalls);
      - ``subsample``   adaptively decimate the accepted cadence: every
        overflow doubles the stride between accepted snapshots, sustained
        slack halves it (compute never stalls, surviving snapshots are
        evenly spaced in step number).
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

POLICIES = ("block", "drop-oldest", "subsample")


def to_host(arrays: dict) -> dict[str, np.ndarray]:
    """Materialize a dict of arrays (jax or numpy) on the host, no copy."""
    return {k: np.asarray(v) for k, v in arrays.items()}


@dataclasses.dataclass
class Snapshot:
    """One staged unit of work: host copies of the arrays of one step.

    ``domain``/``n_domains`` identify the contributor group this part
    belongs to when the step was partitioned over groups (engine
    ``domains > 1``); reducers use them to contribute each owned element
    exactly once so per-group outputs merge back to the global answer.
    """
    step: int
    kind: str                         # "amr" (tree arrays) | "tensors"
    arrays: dict[str, np.ndarray]
    meta: dict = dataclasses.field(default_factory=dict)
    domain: int = 0                   # contributor group of this part
    n_domains: int = 1                # groups the step was split into
    _bufset: "_BufferSet | None" = None


class _BufferSet:
    """One reusable set of host buffers (name -> ndarray)."""

    def __init__(self):
        self.buffers: dict[str, np.ndarray] = {}

    def fill(self, arrays: dict[str, np.ndarray]):
        """Copy ``arrays`` in, reusing allocations when shapes match.

        Returns (host arrays, reuses, allocs, bytes) — the caller folds
        the counters into the shared stats under its own lock.
        """
        out = {}
        reuses = allocs = nbytes = 0
        for name, src in arrays.items():
            dst = self.buffers.get(name)
            if dst is not None and dst.shape == src.shape \
                    and dst.dtype == src.dtype:
                np.copyto(dst, src)
                reuses += 1
            else:
                dst = np.array(src, copy=True)
                self.buffers[name] = dst
                allocs += 1
            nbytes += dst.nbytes
            out[name] = dst
        # drop buffers for names that disappeared (AMR trees change size)
        for name in list(self.buffers):
            if name not in arrays:
                del self.buffers[name]
        return out, reuses, allocs, nbytes


@dataclasses.dataclass
class StagingStats:
    pushed: int = 0
    accepted: int = 0
    dropped: int = 0          # incoming snapshots rejected (subsample/full)
    evicted: int = 0          # queued snapshots displaced (drop-oldest)
    buffer_reuses: int = 0
    buffer_allocs: int = 0
    bytes_staged: int = 0
    block_seconds: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class StagingArea:
    """Bounded, policy-governed hand-off between compute and analysis."""

    def __init__(self, *, capacity: int = 4, policy: str = "drop-oldest",
                 n_buffers: int | None = None, on_evict=None):
        assert policy in POLICIES, policy
        assert capacity >= 1
        self.capacity = capacity
        self.policy = policy
        #: called with each evicted Snapshot *after* the area lock is
        #: released (drop-oldest displacement only; push-time rejections
        #: are visible to the caller through push's return value)
        self.on_evict = on_evict
        # enough sets for every queue slot + one being filled + one being
        # reduced per consumer; sized generously by the engine.
        self._free: list[_BufferSet] = [
            _BufferSet() for _ in range(n_buffers or capacity + 2)]
        self._queue: list[Snapshot] = []
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False
        self._stride = 1              # subsample decimation stride
        self._slack = 0               # consecutive easy pushes (for decay)
        self.stats = StagingStats()

    # -------------------------------------------------------------- push
    def push(self, step: int, arrays: dict, *, kind: str = "amr",
             meta: dict | None = None, domain: int = 0,
             n_domains: int = 1) -> bool:
        """Stage one snapshot; returns False if it was dropped.

        Never blocks unless ``policy == "block"``. The arrays are copied
        into a pooled host buffer set before return. ``on_evict``
        callbacks for drop-oldest victims fire after the lock is
        released, before push returns.
        """
        victims: list[Snapshot] = []
        try:
            return self._push(step, arrays, kind, meta, domain, n_domains,
                              victims)
        finally:
            if self.on_evict is not None:
                for v in victims:
                    self.on_evict(v)

    def _push(self, step, arrays, kind, meta, domain, n_domains,
              victims: list) -> bool:
        with self._lock:
            if self._closed:
                raise RuntimeError("staging area is closed")
            self.stats.pushed += 1
            if self.policy == "subsample":
                if step % self._stride != 0:
                    self.stats.dropped += 1
                    return False
            while len(self._queue) >= self.capacity or not self._free:
                if self.policy == "block":
                    t0 = time.perf_counter()
                    self._not_full.wait(timeout=0.5)
                    self.stats.block_seconds += time.perf_counter() - t0
                    if self._closed:
                        raise RuntimeError("staging area is closed")
                    continue
                if self.policy == "drop-oldest" and self._queue:
                    victim = self._queue.pop(0)
                    self._reclaim(victim)
                    self.stats.evicted += 1
                    victims.append(victim)
                    continue
                # subsample overflow (or drop-oldest with everything
                # in-flight): reject the incoming snapshot
                if self.policy == "subsample":
                    self._stride = min(self._stride * 2, 1 << 16)
                    self._slack = 0
                self.stats.dropped += 1
                return False
            if self.policy == "subsample":
                self._slack += 1
                if self._stride > 1 and self._slack * 2 > self.capacity:
                    self._stride //= 2
                    self._slack = 0
            bufset = self._free.pop()
        # the (possibly large) device->host copy runs without the lock so
        # consumers keep popping/releasing; the buffer set is reserved
        try:
            host, reuses, allocs, nbytes = bufset.fill(to_host(arrays))
        except BaseException:
            with self._lock:       # failed copy must not leak the pool
                self._free.append(bufset)
                self._not_full.notify()
            raise
        snap = Snapshot(step=step, kind=kind, arrays=host,
                        meta=dict(meta or {}), domain=domain,
                        n_domains=n_domains, _bufset=bufset)
        with self._lock:
            self.stats.buffer_reuses += reuses
            self.stats.buffer_allocs += allocs
            self.stats.bytes_staged += nbytes
            if len(self._queue) >= self.capacity:
                # another producer filled the queue during our copy
                if self.policy == "drop-oldest":
                    victim = self._queue.pop(0)
                    self._reclaim(victim)
                    self.stats.evicted += 1
                    victims.append(victim)
                elif self.policy != "block":
                    self._reclaim(snap)
                    self.stats.dropped += 1
                    return False
                else:
                    while len(self._queue) >= self.capacity:
                        if self._closed:
                            self._reclaim(snap)
                            raise RuntimeError("staging area is closed")
                        t0 = time.perf_counter()
                        self._not_full.wait(timeout=0.5)
                        self.stats.block_seconds += \
                            time.perf_counter() - t0
            self._queue.append(snap)
            self.stats.accepted += 1
            self._not_empty.notify()
            return True

    # --------------------------------------------------------------- pop
    def pop(self, timeout: float | None = None) -> Snapshot | None:
        """Take the oldest staged snapshot; None on timeout/close."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._lock:
            while not self._queue:
                if self._closed:
                    return None
                remaining = None if deadline is None else \
                    deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    return None
                self._not_empty.wait(timeout=remaining if remaining is not None
                                     else 0.5)
            snap = self._queue.pop(0)
            # a queue slot opened up for block-policy producers; the
            # buffer set stays owned by the snapshot until release()
            self._not_full.notify()
            return snap

    def release(self, snap: Snapshot) -> None:
        """Return a popped snapshot's buffer set to the pool."""
        if snap._bufset is None:
            return
        with self._lock:
            self._free.append(snap._bufset)
            snap._bufset = None
            self._not_full.notify()

    def _reclaim(self, snap: Snapshot) -> None:
        # caller holds the lock
        if snap._bufset is not None:
            self._free.append(snap._bufset)
            snap._bufset = None

    # ------------------------------------------------------------- admin
    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed
