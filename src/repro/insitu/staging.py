"""Device→host staging area for in-transit analysis (paper fig. 1).

Models Hercule's staging nodes: the compute flow hands a snapshot to the
staging area and immediately continues; the analysis flow drains it at
its own pace. Three pieces:

  * **double-buffered host buffers** — a small pool of reusable host-side
    buffer sets. The push copies device (or live host) arrays into a free
    buffer set, so compute may mutate its arrays right after ``push``
    returns and steady-state pushes reuse memory instead of allocating
    (classic double buffering: one set being filled while others are in
    flight through the queue/workers).
  * **bounded queue** — at most ``capacity`` staged snapshots wait for the
    engine; in-flight snapshots (popped, being reduced) hold their buffer
    set until :meth:`release`.
  * **explicit backpressure policy** when the queue (or buffer pool) is
    full:
      - ``block``       compute waits for space (lossless, may stall);
      - ``drop-oldest`` evict the oldest waiting snapshot, accept the new
        one (viewers always see the freshest data; compute never stalls);
      - ``subsample``   adaptively decimate the accepted cadence: a
        PID-style controller (:class:`StrideController`) watches the
        observed queue depth and steers the stride between accepted
        snapshots toward the consumer's actual drain rate (compute never
        stalls, surviving snapshots are evenly spaced in step number).
"""
from __future__ import annotations

import dataclasses
import json
import math
import multiprocessing
import os
import struct
import threading
import time

import numpy as np

POLICIES = ("block", "drop-oldest", "subsample")


class StrideController:
    """PID-style subsample-stride control from observed queue depth.

    Replaces the old heuristic (double on overflow, halve on sustained
    slack), whose step response hunted between extremes. The plant
    state is the queue fill fraction; the setpoint keeps the queue
    half full — enough slack to absorb bursts, enough depth that the
    consumer never starves. The control signal moves ``log2(stride)``,
    so corrections are multiplicative and the stride stays a positive
    integer; under constant load it converges to the consumer's service
    ratio instead of oscillating (asserted by
    ``tests/test_insitu.py::test_subsample_stride_converges``).

    ``observe(depth)`` runs once per push attempt; ``overflow()`` adds
    a hard kick when the queue actually overflowed (the integral is
    also floored at zero there — anti-windup, so a long full-queue
    episode does not leave a huge stride to unwind).

    Gain note: the output is an *increment* to log2(stride), so each
    term acts one integration higher than its name — the P term is the
    loop's integral action (the queue depth already integrates the
    accept−drain rate mismatch) and the D term its proportional
    damping. ``ki`` therefore defaults to 0: a true double-integral
    path destabilizes high service ratios; the term stays available
    for plants with persistent depth bias.
    """

    MAX_STRIDE = 1 << 16

    def __init__(self, capacity: int, *, setpoint: float = 0.5,
                 kp: float = 0.03, ki: float = 0.0, kd: float = 0.5):
        self.capacity = max(1, int(capacity))
        self.setpoint = setpoint
        self.kp, self.ki, self.kd = kp, ki, kd
        self._log = 0.0                    # log2 of the stride
        self._integral = 0.0
        self._prev: float | None = None

    @property
    def stride(self) -> int:
        return max(1, int(round(2.0 ** self._log)))

    def observe(self, depth: int) -> int:
        """Update from the current queue depth; returns the new stride."""
        err = depth / self.capacity - self.setpoint
        self._integral = min(max(self._integral + err, -4.0), 4.0)
        deriv = 0.0 if self._prev is None else err - self._prev
        self._prev = err
        u = self.kp * err + self.ki * self._integral + self.kd * deriv
        self._log = min(max(self._log + u, 0.0),
                        math.log2(self.MAX_STRIDE))
        return self.stride

    def overflow(self) -> None:
        """The queue/pool actually overflowed: step the stride up hard."""
        self._log = min(self._log + 1.0, math.log2(self.MAX_STRIDE))
        self._integral = max(self._integral, 0.0)


#: shared stride-controller state words appended to the ShmStagingArea
#: control segment: log2(stride), PID integral, previous error — Q31.32
#: fixed point in int64, with INT64_MIN marking "no sample yet"
N_CTRL_WORDS = 3
_CTRL_SCALE = float(1 << 32)
_CTRL_UNSET = np.iinfo(np.int64).min


class SharedStrideController(StrideController):
    """StrideController whose state lives in shared int64 control words.

    The multi-producer subsample fix (ROADMAP carried-over item): every
    process bound to a :class:`ShmStagingArea` — the creating producer
    and each :meth:`ShmStagingArea.attach` side — views the *same*
    three state words, so the decimation stride converges once for the
    whole producer fleet instead of independently per process (which
    made survivors unevenly spaced and double-corrected shared queue
    depth). All mutations happen inside ``_push`` under the area's
    cross-process lock; construction never resets the words, so an
    attaching producer adopts whatever stride the fleet has already
    converged to.
    """

    def __init__(self, capacity: int, words, *, setpoint: float = 0.5,
                 kp: float = 0.03, ki: float = 0.0, kd: float = 0.5):
        self._w = words
        self.capacity = max(1, int(capacity))
        self.setpoint = setpoint
        self.kp, self.ki, self.kd = kp, ki, kd

    @property
    def _log(self) -> float:
        return float(self._w[0]) / _CTRL_SCALE

    @_log.setter
    def _log(self, v: float) -> None:
        self._w[0] = int(round(v * _CTRL_SCALE))

    @property
    def _integral(self) -> float:
        return float(self._w[1]) / _CTRL_SCALE

    @_integral.setter
    def _integral(self, v: float) -> None:
        self._w[1] = int(round(v * _CTRL_SCALE))

    @property
    def _prev(self) -> float | None:
        w = int(self._w[2])
        return None if w == _CTRL_UNSET else w / _CTRL_SCALE

    @_prev.setter
    def _prev(self, v: float | None) -> None:
        self._w[2] = _CTRL_UNSET if v is None \
            else int(round(v * _CTRL_SCALE))

    def freeze(self) -> StrideController:
        """Plain host-side copy (survives segment detach/unlink)."""
        plain = StrideController(self.capacity, setpoint=self.setpoint,
                                 kp=self.kp, ki=self.ki, kd=self.kd)
        plain._log, plain._integral = self._log, self._integral
        plain._prev = self._prev
        return plain


def to_host(arrays: dict) -> dict[str, np.ndarray]:
    """Materialize a dict of arrays (jax or numpy) on the host, no copy."""
    return {k: np.asarray(v) for k, v in arrays.items()}


@dataclasses.dataclass
class Snapshot:
    """One staged unit of work: host copies of the arrays of one step.

    ``domain``/``n_domains`` identify the contributor group this part
    belongs to when the step was partitioned over groups (engine
    ``domains > 1``); reducers use them to contribute each owned element
    exactly once so per-group outputs merge back to the global answer.
    """
    step: int
    kind: str                         # "amr" (tree arrays) | "tensors"
    arrays: dict[str, np.ndarray]
    meta: dict = dataclasses.field(default_factory=dict)
    domain: int = 0                   # contributor group of this part
    n_domains: int = 1                # groups the step was split into
    _bufset: "_BufferSet | None" = None
    _slot: int | None = None          # shm slot (ShmStagingArea consumers)


class _BufferSet:
    """One reusable set of host buffers (name -> ndarray)."""

    def __init__(self):
        self.buffers: dict[str, np.ndarray] = {}

    def fill(self, arrays: dict):
        """Copy ``arrays`` (host or device) in, reusing allocations.

        Returns (staged arrays, reuses, allocs, bytes) — the caller
        folds the counters into the shared stats under its own lock.
        Subclass hook: :class:`~repro.insitu.device.DeviceStagingArea`
        swaps in a device-resident buffer set with the same contract.
        """
        out = {}
        reuses = allocs = nbytes = 0
        for name, raw in arrays.items():
            src = np.asarray(raw)          # device arrays land here once
            dst = self.buffers.get(name)
            if dst is not None and dst.shape == src.shape \
                    and dst.dtype == src.dtype:
                np.copyto(dst, src)
                reuses += 1
            else:
                dst = np.array(src, copy=True)
                self.buffers[name] = dst
                allocs += 1
            nbytes += dst.nbytes
            out[name] = dst
        # drop buffers for names that disappeared (AMR trees change size)
        for name in list(self.buffers):
            if name not in arrays:
                del self.buffers[name]
        return out, reuses, allocs, nbytes


#: StagingStats field order — also the shm control-word stats layout of
#: :class:`_ShmStats` (one int64 word per field, block_seconds stored
#: as integer nanoseconds; DESIGN.md §15)
STAT_FIELDS = ("pushed", "accepted", "dropped", "evicted",
               "buffer_reuses", "buffer_allocs", "bytes_staged",
               "block_seconds", "popped", "released")
N_STAT_WORDS = len(STAT_FIELDS)


@dataclasses.dataclass
class StagingStats:
    pushed: int = 0
    accepted: int = 0
    dropped: int = 0          # incoming snapshots rejected (subsample/full)
    evicted: int = 0          # queued snapshots displaced (drop-oldest)
    buffer_reuses: int = 0
    buffer_allocs: int = 0
    bytes_staged: int = 0
    block_seconds: float = 0.0
    popped: int = 0           # snapshots taken by a consumer
    released: int = 0         # popped snapshots whose buffers returned

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def freeze(self) -> "StagingStats":
        return self          # already host-resident (detach idempotence)


class _ShmStats:
    """StagingStats view over shared control words (ShmStagingArea).

    Producer and every attached consumer bind the *same* int64 words,
    so counters incremented on either side of the process boundary are
    visible to both — ``stats`` is truthful from any end. All mutations
    happen under the area's cross-process lock; reads are single-word
    int64 loads (torn values impossible). ``block_seconds`` is stored
    as integer nanoseconds so it shares the int64 word layout.
    """

    __slots__ = ("_w",)

    def __init__(self, words):
        object.__setattr__(self, "_w", words)

    def __getattr__(self, name):
        try:
            i = STAT_FIELDS.index(name)
        except ValueError:
            raise AttributeError(name) from None
        v = int(self._w[i])
        return v / 1e9 if name == "block_seconds" else v

    def __setattr__(self, name, value):
        i = STAT_FIELDS.index(name)   # raises ValueError on foreign attrs
        self._w[i] = int(round(value * 1e9)) \
            if name == "block_seconds" else int(value)

    def as_dict(self) -> dict:
        return {f: getattr(self, f) for f in STAT_FIELDS}

    def freeze(self) -> StagingStats:
        """Materialize a plain StagingStats (survives segment unlink)."""
        return StagingStats(**self.as_dict())


class StagingArea:
    """Bounded, policy-governed hand-off between compute and analysis."""

    #: buffer-set factory — subclasses swap the staging residency
    #: (``DeviceStagingArea`` keeps snapshots as jax device arrays)
    BUFFER_SET: type = _BufferSet

    def __init__(self, *, capacity: int = 4, policy: str = "drop-oldest",
                 n_buffers: int | None = None, on_evict=None):
        assert policy in POLICIES, policy
        assert capacity >= 1
        self.capacity = capacity
        self.policy = policy
        #: called with each evicted Snapshot *after* the area lock is
        #: released (drop-oldest displacement only; push-time rejections
        #: are visible to the caller through push's return value)
        self.on_evict = on_evict
        # enough sets for every queue slot + one being filled + one being
        # reduced per consumer; sized generously by the engine.
        self._free: list[_BufferSet] = [
            self.BUFFER_SET() for _ in range(n_buffers or capacity + 2)]
        self._queue: list[Snapshot] = []
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False
        self._ctrl = StrideController(capacity)   # subsample decimation
        self.stats = StagingStats()

    @property
    def stride(self) -> int:
        """Current subsample decimation stride (1 = accept every step)."""
        return self._ctrl.stride

    # -------------------------------------------------------------- push
    def push(self, step: int, arrays: dict, *, kind: str = "amr",
             meta: dict | None = None, domain: int = 0,
             n_domains: int = 1) -> bool:
        """Stage one snapshot; returns False if it was dropped.

        Never blocks unless ``policy == "block"``. The arrays are copied
        into a pooled host buffer set before return. ``on_evict``
        callbacks for drop-oldest victims fire after the lock is
        released, before push returns.
        """
        victims: list[Snapshot] = []
        try:
            return self._push(step, arrays, kind, meta, domain, n_domains,
                              victims)
        finally:
            if self.on_evict is not None:
                for v in victims:
                    self.on_evict(v)

    def _push(self, step, arrays, kind, meta, domain, n_domains,
              victims: list) -> bool:
        with self._lock:
            if self._closed:
                raise RuntimeError("staging area is closed")
            self.stats.pushed += 1
            if self.policy == "subsample":
                stride = self._ctrl.observe(len(self._queue))
                if step % stride != 0:
                    self.stats.dropped += 1
                    return False
            while len(self._queue) >= self.capacity or not self._free:
                if self.policy == "block":
                    t0 = time.perf_counter()
                    self._not_full.wait(timeout=0.5)
                    self.stats.block_seconds += time.perf_counter() - t0
                    if self._closed:
                        raise RuntimeError("staging area is closed")
                    continue
                if self.policy == "drop-oldest" and self._queue:
                    victim = self._queue.pop(0)
                    self._reclaim(victim)
                    self.stats.evicted += 1
                    victims.append(victim)
                    continue
                # subsample overflow (or drop-oldest with everything
                # in-flight): reject the incoming snapshot
                if self.policy == "subsample":
                    self._ctrl.overflow()
                self.stats.dropped += 1
                return False
            bufset = self._free.pop()
        # the (possibly large) staging copy runs without the lock so
        # consumers keep popping/releasing; the buffer set is reserved
        try:
            host, reuses, allocs, nbytes = bufset.fill(arrays)
        except BaseException:
            with self._lock:       # failed copy must not leak the pool
                self._free.append(bufset)
                self._not_full.notify()
            raise
        snap = Snapshot(step=step, kind=kind, arrays=host,
                        meta=dict(meta or {}), domain=domain,
                        n_domains=n_domains, _bufset=bufset)
        with self._lock:
            self.stats.buffer_reuses += reuses
            self.stats.buffer_allocs += allocs
            self.stats.bytes_staged += nbytes
            if len(self._queue) >= self.capacity:
                # another producer filled the queue during our copy
                if self.policy == "drop-oldest":
                    victim = self._queue.pop(0)
                    self._reclaim(victim)
                    self.stats.evicted += 1
                    victims.append(victim)
                elif self.policy != "block":
                    self._reclaim(snap)
                    self.stats.dropped += 1
                    return False
                else:
                    while len(self._queue) >= self.capacity:
                        if self._closed:
                            self._reclaim(snap)
                            raise RuntimeError("staging area is closed")
                        t0 = time.perf_counter()
                        self._not_full.wait(timeout=0.5)
                        self.stats.block_seconds += \
                            time.perf_counter() - t0
            self._queue.append(snap)
            self.stats.accepted += 1
            self._not_empty.notify()
            return True

    # --------------------------------------------------------------- pop
    def pop(self, timeout: float | None = None) -> Snapshot | None:
        """Take the oldest staged snapshot; None on timeout/close."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._lock:
            while not self._queue:
                if self._closed:
                    return None
                remaining = None if deadline is None else \
                    deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    return None
                self._not_empty.wait(timeout=remaining if remaining is not None
                                     else 0.5)
            snap = self._queue.pop(0)
            # a queue slot opened up for block-policy producers; the
            # buffer set stays owned by the snapshot until release()
            self.stats.popped += 1
            self._not_full.notify()
            return snap

    def release(self, snap: Snapshot) -> None:
        """Return a popped snapshot's buffer set to the pool."""
        if snap._bufset is None:
            return
        with self._lock:
            self._free.append(snap._bufset)
            snap._bufset = None
            self.stats.released += 1
            self._not_full.notify()

    def _reclaim(self, snap: Snapshot) -> None:
        # caller holds the lock
        if snap._bufset is not None:
            self._free.append(snap._bufset)
            snap._bufset = None

    # ------------------------------------------------------------- admin
    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed


# ===================================================================== shm
#
# Cross-process twin of StagingArea: the slabs live in
# ``multiprocessing.shared_memory`` so a *process* lane pops snapshots
# without the producer's GIL and without any pickle round trip of the
# bulk data. Layout:
#
#   control segment (int64 words):
#     [0] closed   [1] q_head   [2] q_count   [3] n_slots
#     [4          .. 4+n)   queue ring of slot ids (oldest at q_head)
#     [4+n        .. 4+2n)  per-slot state (FREE/RESERVED/QUEUED/INFLIGHT)
#     [4+2n       .. 4+6n)  per-slot meta: step, generation, domain, kind
#     [4+6n       .. 4+6n+N_STAT_WORDS)  shared StagingStats counters
#       (STAT_FIELDS order, block_seconds as integer ns): producer and
#       consumer mutate the same words under the lock, so stats() is
#       truthful from either side of the process boundary
#     [4+6n+N_STAT_WORDS .. +N_CTRL_WORDS)  SharedStrideController state
#       (log2-stride, integral, prev-error as Q31.32 fixed point) —
#       every bound producer shares one subsample policy
#
#   one data segment per slot, resized (new generation) when a snapshot
#   outgrows it — steady-state pushes reuse the mapping, the
#   double-buffer discipline of ``_BufferSet`` carried across processes:
#     [u64 header_len][JSON header][pad to 64][array payloads, 64-aligned]
#
# The JSON header (descriptor table: name/dtype/shape/offset per array,
# plus kind/meta) is the only non-raw bytes crossing the boundary — no
# pickle anywhere on the push/pop path. push() copies each array exactly
# once, straight into the mapped slab; pop() returns zero-copy views.
#
# _push deliberately mirrors StagingArea._push's backpressure machine
# rather than sharing it: the two sit on different primitives (pooled
# ndarray buffers + threading.Condition vs shm slot states +
# multiprocessing.Condition). Keep their policy semantics in lockstep —
# tests/test_lane_backend.py enforces drop-oldest parity.

_FREE, _RESERVED, _QUEUED, _INFLIGHT = 0, 1, 2, 3
_KIND_CODES = {"amr": 0, "tensors": 1}
_KIND_NAMES = {v: k for k, v in _KIND_CODES.items()}
_ALIGN = 64


def _align(n: int) -> int:
    return -(-n // _ALIGN) * _ALIGN


def _attach_shm(name: str, untrack: bool = False):
    """Attach an existing shared-memory segment without tracker churn.

    ``untrack`` marks an attach from a process that did not create the
    segment: on 3.13+ it skips resource-tracker registration outright
    (``track=False``). On 3.10-3.12 lane processes share the parent's
    tracker, where the duplicate registration is a set-add no-op and
    the creating side's ``unlink`` clears the single cache entry — so
    no explicit unregister is needed (or safe: it would strip the
    parent's registration, bpo-39959's other edge).
    """
    from multiprocessing import shared_memory
    if untrack:
        try:
            return shared_memory.SharedMemory(name=name, track=False)
        except TypeError:   # track= is 3.13+
            pass
    return shared_memory.SharedMemory(name=name)


class _CrashSafeCondition:
    """Condition-shaped wakeup channel a SIGKILLed waiter cannot poison.

    ``multiprocessing.Condition.notify`` blocks on a ``_woken_count``
    handshake: after releasing a sleeper it waits for that sleeper to
    acknowledge. A lane killed with SIGKILL while parked in ``wait()``
    never acknowledges, so the *notifier* — the parent, holding the
    area lock — hangs forever (and everyone behind the lock with it).
    This wrapper keeps Condition's call shape (wait under the lock,
    notify/notify_all) but signals through a bare semaphore whose
    ``release`` can never block. The trade: no exact-wakeup accounting
    — a notify with no waiter leaves a stale token (one future
    spurious wakeup), and notify_all releases a fixed burst. Both are
    harmless here because every wait site loops on its predicate with
    a bounded timeout.
    """

    def __init__(self, lock, ctx):
        self._lock = lock
        self._sem = ctx.Semaphore(0)

    def wait(self, timeout: float | None = None) -> bool:
        self._lock.release()
        try:
            return self._sem.acquire(True, timeout)
        finally:
            self._lock.acquire()

    def notify(self, n: int = 1) -> None:
        for _ in range(n):
            self._sem.release()

    def notify_all(self) -> None:
        self.notify(16)


@dataclasses.dataclass
class ShmHandle:
    """Picklable attach spec for a lane process (see ShmStagingArea)."""
    uid: str
    pid: int                 # creating process (attach untracks elsewhere)
    control: str
    n_slots: int
    capacity: int
    lock: object
    not_empty: object
    not_full: object


class ShmStagingArea:
    """StagingArea over shared memory: producer in-parent, consumer anywhere.

    Same bounded-queue/backpressure semantics as :class:`StagingArea`
    (the policies, stats and ``on_evict`` contract are identical); the
    buffer pool is a ring of shared-memory slots so the consumer side
    may be an OS process. The parent constructs it and pushes; a lane
    process calls :meth:`attach` on :meth:`handle` and pops. ``close``
    only signals; :meth:`unlink` reclaims the segments once every
    consumer detached (the owning backend calls it after joining lanes).
    """

    def __init__(self, *, capacity: int = 4, policy: str = "drop-oldest",
                 n_slots: int | None = None, on_evict=None,
                 min_slot_bytes: int = 1 << 16, mp_context=None,
                 sync=None):
        from multiprocessing import shared_memory
        assert policy in POLICIES, policy
        assert capacity >= 1
        self.capacity = capacity
        self.policy = policy
        self.on_evict = on_evict
        self.min_slot_bytes = min_slot_bytes
        n = n_slots or capacity + 2
        ctx = mp_context or multiprocessing.get_context("spawn")
        self._uid = f"hx{os.getpid():x}_{os.urandom(4).hex()}"
        self._shm = shared_memory.SharedMemory(
            create=True,
            size=(4 + 6 * n + N_STAT_WORDS + N_CTRL_WORDS) * 8,
            name=f"{self._uid}ctl")
        if sync is not None:
            # externally owned primitives (the persistent lane pool:
            # a pooled lane inherited them at spawn, long before this
            # area existed — see insitu.lanes.LanePool)
            self._lock, self._not_empty, self._not_full = sync
        else:
            self._lock = ctx.Lock()
            self._not_empty = _CrashSafeCondition(self._lock, ctx)
            self._not_full = _CrashSafeCondition(self._lock, ctx)
        self._bind(self._shm, n)
        self._words[:] = 0
        self._words[3] = n
        self._ctrl._prev = None   # restore the "no sample yet" sentinel
        #: producer-side segment cache: slot -> (gen, SharedMemory)
        self._segs: dict[int, tuple[int, object]] = {}
        self._consumer = False
        self._untrack = False

    @property
    def stride(self) -> int:
        """Current subsample decimation stride (1 = accept every step).

        Shared across every bound producer: the controller state lives
        in the segment's control words (:class:`SharedStrideController`).
        """
        return self._ctrl.stride

    def _bind(self, ctrl, n: int) -> None:
        self.n_slots = n
        self._words = np.ndarray(
            (4 + 6 * n + N_STAT_WORDS + N_CTRL_WORDS,), np.int64,
            buffer=ctrl.buf)
        self._ring = self._words[4:4 + n]
        self._state = self._words[4 + n:4 + 2 * n]
        self._meta = self._words[4 + 2 * n:4 + 6 * n].reshape(n, 4)
        # both ends mutate the same counters (under the shared lock)
        self.stats = _ShmStats(
            self._words[4 + 6 * n:4 + 6 * n + N_STAT_WORDS])
        # ... and the same subsample-stride state (multi-producer policy)
        self._ctrl = SharedStrideController(
            self.capacity, self._words[4 + 6 * n + N_STAT_WORDS:])

    # ---------------------------------------------------------- handle
    def handle(self) -> ShmHandle:
        return ShmHandle(uid=self._uid, pid=os.getpid(),
                         control=self._shm.name,
                         n_slots=self.n_slots, capacity=self.capacity,
                         lock=self._lock, not_empty=self._not_empty,
                         not_full=self._not_full)

    def spec(self) -> dict:
        """Primitive-free attach spec (queue-transportable).

        ``multiprocessing`` locks/conditions only pickle during process
        *creation* — a handle sent over a queue to an already-running
        pooled lane must not carry them. The lane rebuilds a full
        :class:`ShmHandle` from this spec plus the sync primitives it
        inherited at spawn (the same objects this area was constructed
        with via ``sync=``; see ``insitu.lanes.LanePool``).
        """
        return {"uid": self._uid, "pid": os.getpid(),
                "control": self._shm.name, "n_slots": self.n_slots,
                "capacity": self.capacity}

    @staticmethod
    def handle_from_spec(spec: dict, sync) -> ShmHandle:
        """Rebuild an attachable handle from :meth:`spec` + inherited sync."""
        lock, not_empty, not_full = sync
        return ShmHandle(uid=spec["uid"], pid=spec["pid"],
                         control=spec["control"], n_slots=spec["n_slots"],
                         capacity=spec["capacity"], lock=lock,
                         not_empty=not_empty, not_full=not_full)

    @classmethod
    def attach(cls, handle: ShmHandle) -> "ShmStagingArea":
        """Consumer-side view (a lane process): pop/release/close only."""
        self = cls.__new__(cls)
        self._uid = handle.uid
        self.capacity = handle.capacity
        self._untrack = handle.pid != os.getpid()
        self._shm = _attach_shm(handle.control, self._untrack)
        self._lock = handle.lock
        self._not_empty = handle.not_empty
        self._not_full = handle.not_full
        self._bind(self._shm, handle.n_slots)
        self._segs = {}
        self.on_evict = None
        self._consumer = True
        return self

    # -------------------------------------------------------------- push
    def push(self, step: int, arrays: dict, *, kind: str = "amr",
             meta: dict | None = None, domain: int = 0,
             n_domains: int = 1) -> bool:
        victims: list[Snapshot] = []
        try:
            return self._push(step, arrays, kind, meta, domain, n_domains,
                              victims)
        finally:
            if self.on_evict is not None:
                for v in victims:
                    self.on_evict(v)

    def _evict_oldest(self, victims: list) -> None:
        # caller holds the lock; q_count > 0
        slot = int(self._ring[self._words[1]])
        vstep, _, vdom, vkind = (int(x) for x in self._meta[slot])
        self._words[1] = (self._words[1] + 1) % self.n_slots
        self._words[2] -= 1
        self._state[slot] = _FREE
        self.stats.evicted += 1
        victims.append(Snapshot(step=vstep, arrays={},
                                kind=_KIND_NAMES.get(vkind, "amr"),
                                domain=vdom))

    def _data_name(self, slot: int, gen: int) -> str:
        return f"{self._uid}s{slot}g{gen}"

    def _wait_block(self) -> None:
        t0 = time.perf_counter()
        self._not_full.wait(timeout=0.5)
        self.stats.block_seconds += time.perf_counter() - t0

    def _push(self, step, arrays, kind, meta, domain, n_domains,
              victims: list) -> bool:
        with self._lock:
            if self._words[0]:
                raise RuntimeError("staging area is closed")
            self.stats.pushed += 1
            if self.policy == "subsample":
                stride = self._ctrl.observe(int(self._words[2]))
                if step % stride != 0:
                    self.stats.dropped += 1
                    return False
            while True:
                free = np.flatnonzero(self._state == _FREE)
                if self._words[2] < self.capacity and free.size:
                    break
                if self.policy == "block":
                    self._wait_block()
                    if self._words[0]:
                        raise RuntimeError("staging area is closed")
                    continue
                if self.policy == "drop-oldest" and self._words[2]:
                    self._evict_oldest(victims)
                    continue
                if self.policy == "subsample":
                    self._ctrl.overflow()
                self.stats.dropped += 1
                return False
            slot = int(free[0])
            self._state[slot] = _RESERVED
        # the (possibly large) copy into the slab runs without the lock
        try:
            gen, nbytes, reused = self._fill(slot, step, arrays, kind,
                                             meta, domain, n_domains)
        except BaseException:
            with self._lock:
                self._state[slot] = _FREE
                self._not_full.notify()
            raise
        with self._lock:
            self.stats.buffer_reuses += int(reused)
            self.stats.buffer_allocs += int(not reused)
            self.stats.bytes_staged += nbytes
            if self._words[2] >= self.capacity:
                # another producer filled the queue during our copy
                if self.policy == "drop-oldest":
                    self._evict_oldest(victims)
                elif self.policy != "block":
                    self._state[slot] = _FREE
                    self.stats.dropped += 1
                    return False
                else:
                    while self._words[2] >= self.capacity:
                        if self._words[0]:
                            self._state[slot] = _FREE
                            raise RuntimeError("staging area is closed")
                        self._wait_block()
            self._meta[slot] = (step, gen, domain,
                                _KIND_CODES.get(kind, 0))
            self._ring[(self._words[1] + self._words[2]) % self.n_slots] \
                = slot
            self._words[2] += 1
            self._state[slot] = _QUEUED
            self.stats.accepted += 1
            self._not_empty.notify()
            return True

    def _fill(self, slot: int, step, arrays, kind, meta, domain,
              n_domains) -> tuple[int, int, bool]:
        """Copy one snapshot into the slot's slab; returns (gen, bytes,
        reused) — ``reused`` False when the slab had to grow."""
        from multiprocessing import shared_memory
        host = [(name, np.ascontiguousarray(a))
                for name, a in to_host(arrays).items()]
        descs, off = [], 0
        for name, a in host:
            off = _align(off)
            descs.append({"name": name, "dtype": str(a.dtype),
                          "shape": list(a.shape), "offset": off})
            off += a.nbytes
        header = json.dumps({
            "step": int(step), "kind": kind, "meta": dict(meta or {}),
            "domain": int(domain), "n_domains": int(n_domains),
            "arrays": descs}).encode()
        base = _align(8 + len(header))
        total = base + off
        ent = self._segs.get(slot)
        reused = ent is not None and ent[1].size >= total
        if not reused:
            gen = ent[0] + 1 if ent else 0
            if ent:
                ent[1].close()
                ent[1].unlink()
            size = max(total + total // 4, self.min_slot_bytes)
            seg = shared_memory.SharedMemory(
                create=True, size=size, name=self._data_name(slot, gen))
            self._segs[slot] = (gen, seg)
        gen, seg = self._segs[slot]
        buf = seg.buf
        struct.pack_into("<Q", buf, 0, len(header))
        buf[8:8 + len(header)] = header
        nbytes = 0
        for d, (_, a) in zip(descs, host):
            dst = np.ndarray(a.shape, a.dtype, buffer=buf,
                             offset=base + d["offset"])
            np.copyto(dst, a)
            nbytes += a.nbytes
        return gen, nbytes, reused

    # --------------------------------------------------------------- pop
    def _slot_views(self, slot: int, gen: int):
        ent = self._segs.get(slot)
        if ent is None or ent[0] != gen:
            if ent is not None:
                # a released-but-still-referenced snapshot (the lane
                # loop's previous iteration) may export views of the old
                # generation; tolerate it — the mapping falls with the
                # last view
                self._close_seg(ent[1])
            seg = _attach_shm(self._data_name(slot, gen), self._untrack)
            self._segs[slot] = (gen, seg)
        _, seg = self._segs[slot]
        buf = seg.buf
        (hlen,) = struct.unpack_from("<Q", buf, 0)
        head = json.loads(bytes(buf[8:8 + hlen]).decode())
        base = _align(8 + hlen)
        arrays = {}
        for d in head["arrays"]:
            arrays[d["name"]] = np.ndarray(
                tuple(d["shape"]), np.dtype(d["dtype"]), buffer=buf,
                offset=base + d["offset"])
        return head, arrays

    def pop(self, timeout: float | None = None) -> Snapshot | None:
        """Oldest queued snapshot as zero-copy views into its slab."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._lock:
            while not self._words[2]:
                if self._words[0]:
                    return None
                remaining = None if deadline is None else \
                    deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    return None
                self._not_empty.wait(
                    timeout=remaining if remaining is not None else 0.5)
            slot = int(self._ring[self._words[1]])
            self._words[1] = (self._words[1] + 1) % self.n_slots
            self._words[2] -= 1
            self._state[slot] = _INFLIGHT
            gen = int(self._meta[slot][1])
            self.stats.popped += 1
            self._not_full.notify()
        head, arrays = self._slot_views(slot, gen)
        return Snapshot(step=head["step"], kind=head["kind"], arrays=arrays,
                        meta=head["meta"], domain=head["domain"],
                        n_domains=head["n_domains"], _slot=slot)

    def release(self, snap: Snapshot) -> None:
        """Return a popped snapshot's slab to the ring.

        The snapshot's arrays are views into the slab — they must not be
        used after release (the producer may refill the slot at once).
        """
        if snap._slot is None:
            return
        with self._lock:
            self._state[snap._slot] = _FREE
            snap._slot = None
            self.stats.released += 1
            self._not_full.notify()

    # ------------------------------------------------------------- admin
    def __len__(self) -> int:
        with self._lock:
            return int(self._words[2])

    @property
    def closed(self) -> bool:
        return bool(self._words[0])

    def close(self) -> None:
        """Signal producers/consumers; segments survive until unlink()."""
        with self._lock:
            self._words[0] = 1
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @staticmethod
    def _close_seg(seg) -> None:
        try:
            seg.close()
        except BufferError:
            pass   # a live view still exports the mapping; unlink works

    def detach(self) -> None:
        """Consumer side: drop the segment mappings (no unlink)."""
        for _, seg in self._segs.values():
            self._close_seg(seg)
        self._segs.clear()
        # drop numpy views before closing the mapping they alias; stats
        # and stride state stay readable as frozen host-side copies
        self.stats = self.stats.freeze()
        self._ctrl = self._ctrl.freeze()
        self._words = self._ring = self._state = self._meta = None
        self._close_seg(self._shm)

    def unlink(self) -> None:
        """Owner side: reclaim every shared-memory segment.

        Call after all consumers detached (on Linux their live mappings
        stay valid; the names are gone for new attaches).
        """
        if self._consumer:
            raise RuntimeError("only the creating side may unlink")
        for _, seg in self._segs.values():
            self._close_seg(seg)
            seg.unlink()
        self._segs.clear()
        self.stats = self.stats.freeze()
        self._ctrl = self._ctrl.freeze()
        self._words = self._ring = self._state = self._meta = None
        self._close_seg(self._shm)
        self._shm.unlink()
