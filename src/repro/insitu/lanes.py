"""Pluggable lane runtime: how contributor-group lanes actually execute.

The engine (``insitu/engine.py``) owns the *what*: cadence, partitioning,
the per-step part countdown and manifest finalize. A :class:`LaneBackend`
owns the *how*: the staging transport and the execution context in which
each group's lane drains its staging area, runs the reducer DAG and
lands its Hercule domain.

Two backends register here:

  * ``thread``  — PR-3 semantics, bit for bit: one ``StagingArea`` and
    ``workers`` daemon threads per group, reducing and writing in the
    engine's process through the shared ``ContextWriter``.
  * ``process`` — the paper's per-producer shape with real OS processes:
    each group's lane is a spawned process fed through a
    :class:`~repro.insitu.staging.ShmStagingArea` (shared-memory slabs,
    pickle-free descriptor headers), so reduction *and* the Hercule
    domain writes run fully outside the producer's GIL. Lanes append to
    their own group files (``DomainWriter``) and report the record index
    over a small results queue; the engine commits one manifest per
    step and fsyncs exactly the referenced data files first.

``register_backend`` makes the runtime pluggable — a future MPI or RPC
lane transport slots in without touching the engine.
"""
from __future__ import annotations

import hashlib
import multiprocessing
import pickle
import queue
import threading
import traceback

from ..hercule import api
from ..hercule.database import DomainWriter, HerculeDB, Record
from ..obs import events as obs_events
from ..obs import metrics as obs_metrics
from ..obs.trace import TRACER, Tracer, now_us
from .reducers import ReducerDAG
from .staging import ShmStagingArea, StagingArea, _CrashSafeCondition

BACKENDS: dict[str, type] = {}


def register_backend(name: str, cls: type) -> type:
    """Register (or replace) a lane backend under ``name``."""
    BACKENDS[name] = cls
    return cls


def make_backend(name: str, engine, **kw):
    if name not in BACKENDS:
        raise ValueError(f"unknown lane backend {name!r}; "
                         f"registered: {sorted(BACKENDS)}")
    return BACKENDS[name](engine, **kw)


class LaneBackend:
    """One lane-execution strategy; constructed by and bound to an engine.

    Contract: expose ``stages`` (one push-capable area per contributor
    group, wired to the engine's ``on_evict``), run each accepted part
    through the reducer DAG exactly once, settle it via the engine's
    ``_part_done``/record paths, and surface failures on
    ``engine._errors``. ``stop()`` must not return while a lane could
    still be writing.
    """

    name = ""

    def __init__(self, engine):
        self.engine = engine
        self.stages: list = []

    def start(self) -> None:
        raise NotImplementedError

    def stop(self, timeout: float = 30.0) -> None:
        """Close staging, stop lanes, reclaim transport resources."""
        raise NotImplementedError

    def pre_finalize(self, pend) -> None:
        """Durability hook before a context manifest commits."""

    def telemetry(self) -> dict:
        """Backend-specific counters for ``InTransitEngine.telemetry``."""
        return {}


def reducer_fingerprint(reducers) -> str:
    """Stable id of a reducer configuration (type + pickled state).

    Keys the lane-side DAG cache of the persistent pool: two borrows
    with identical reducer configs hash equal, so the resident lane
    reuses its rebuilt :class:`ReducerDAG` instead of re-unpickling
    and re-validating per borrow.
    """
    payload = pickle.dumps([
        (type(r).__module__, type(r).__qualname__, r.__getstate__())
        for r in reducers])
    return hashlib.sha1(payload).hexdigest()


class ThreadLaneBackend(LaneBackend):
    """In-process worker threads (the original engine execution model).

    With ``engine.device_reduce`` the staging areas are
    :class:`~repro.insitu.device.DeviceStagingArea` — snapshots stay on
    the accelerator and lanes run the DAG through the engine's
    :class:`~repro.insitu.device.DeviceDAGRunner`; everything else
    (queue bounds, policies, eviction countdown) is identical.
    """

    name = "thread"

    def __init__(self, engine, *, workers: int, queue_capacity: int,
                 policy: str, lane_pool: bool = False):
        super().__init__(engine)
        del lane_pool   # validated engine-side: process-lane concern
        area_cls = StagingArea
        if engine.device_reduce and engine.device_reduce != "mesh":
            # mesh reduction stages on host — the runner re-shards each
            # snapshot's leaf table over the mesh itself, so a single
            # device-resident copy would only add a pointless hop
            from .device import DeviceStagingArea
            area_cls = DeviceStagingArea
        self.stages = [
            area_cls(capacity=queue_capacity, policy=policy,
                     n_buffers=queue_capacity + max(1, workers) + 1,
                     on_evict=engine._on_evict)
            for _ in range(engine.n_domains)]
        self._threads = [
            threading.Thread(target=self._worker, args=(area,),
                             name=f"insitu-g{g}-{i}", daemon=True)
            for g, area in enumerate(self.stages)
            for i in range(max(1, workers))]

    def start(self) -> None:
        for t in self._threads:
            t.start()

    def _worker(self, area: StagingArea):
        eng = self.engine
        while True:
            t0 = now_us() if TRACER.enabled else 0.0
            snap = area.pop(timeout=0.25)
            if snap is None:
                eng._run_deferred()
                eng._sweep_ttl()
                if area.closed and len(area) == 0:
                    return
                continue
            tctx = snap.meta.get("_trace")
            if tctx is not None:
                # dequeue latency: staged -> picked up by this lane
                TRACER.record("stage.pop", t0, now_us(), parent=tctx,
                              args={"step": snap.step,
                                    "group": snap.domain})
            try:
                eng._reduce_and_write(snap)
            except BaseException as e:   # surfaced on next submit/drain
                eng._errors.append(e)
                with eng._wlock:
                    eng._failed += 1
                eng._part_done(snap.step, None, None)
            finally:
                area.release(snap)
            eng._run_deferred()

    def stop(self, timeout: float = 30.0) -> None:
        for area in self.stages:
            area.close()
        for t in self._threads:
            if t.ident is not None:      # skip never-started lanes
                t.join(timeout=timeout)
        if any(t.is_alive() for t in self._threads):
            # never close the db under a still-writing worker — a
            # leaked daemon thread beats a corrupted context
            raise TimeoutError(
                "in-transit workers did not stop; database left open")

    def telemetry(self) -> dict:
        return {"kind": "thread", "n_lanes": len(self._threads),
                "lanes_alive": sum(t.is_alive() for t in self._threads)}


def _lane_main(handle, root: str, group: int, reducers, compress: bool,
               durable_parts: bool, results, lane_stats=None) -> None:
    """One process lane: attach shm staging, reduce, write own domain.

    Results-queue wire format (10-tuples; spans/timings/stats/events may
    be None): ``(tag, step, group, records, reducers, meta_or_tb, meta,
    spans, timings, events)`` for "done"; errors carry the traceback in
    slot 5; "exit" carries the lane's cumulative stats dict in slot 8.
    Slot 9 ships the lane's flight-recorder drain (its process-local
    event ring since the previous message — e.g. ``lane.error`` on a
    failed reduce), which the collector relays into the lane's own
    domain of the run ledger.

    ``reducers`` may be a prebuilt :class:`ReducerDAG` (pooled lanes
    pass their fingerprint-cached DAG) or a reducer list. When a popped
    snapshot's meta carries ``_trace`` (the parent's submit-span wire
    context), the lane records stage.pop/reduce/write spans against it
    and ships them home in the "done" message — cross-process parent
    linkage with no clock sync beyond the shared epoch.
    """
    area = ShmStagingArea.attach(handle)
    dag = reducers if isinstance(reducers, ReducerDAG) \
        else ReducerDAG(reducers)
    db = HerculeDB.open(root)
    tracer = Tracer(enabled=True)    # only used when _trace rides in
    # incremental drain of this process's event ring: each message home
    # carries only the events since the previous one (pooled lanes skip
    # events from earlier jobs by starting the mark at the current head)
    ev_mark = obs_events.EVENTS.drain_since(0)[0] if lane_stats else 0

    def drain_events():
        nonlocal ev_mark
        ev_mark, evs = obs_events.EVENTS.drain_since(ev_mark)
        return evs or None

    try:
        while True:
            t_pop = now_us()
            try:
                snap = area.pop(timeout=0.25)
            except BaseException:
                # a transport failure is fatal for the lane: report it
                # (a bare exit would look clean to the collector while
                # this group's queued steps never settle)
                obs_events.EVENTS.emit(obs_events.LANE_ERROR, step=-1,
                                       group=group, stage="transport")
                results.put(("error", -1, group, None, None,
                             traceback.format_exc(), None, None, None,
                             drain_events()))
                return
            if snap is None:
                if area.closed and len(area) == 0:
                    return
                continue
            tctx = snap.meta.get("_trace")
            try:
                r0 = now_us()
                outputs = dag.run(snap)
                r1 = now_us()
                if not outputs:
                    results.put(("skipped", snap.step, group, None, None,
                                 None, None, None, None, drain_events()))
                else:
                    ctx = DomainWriter(db, snap.step)
                    w0 = now_us()
                    for rname, arrays in outputs.items():
                        api.write_object(ctx, "reduced", group, arrays,
                                         reducer=rname, compress=compress)
                    # publish the appended bytes: page cache always (the
                    # manifest committer fsyncs by path), disk if this
                    # lane owns its own durability
                    db.flush_domain(group, sync=durable_parts)
                    w1 = now_us()
                    spans = None
                    if tctx is not None:
                        args = {"step": snap.step, "group": group}
                        tracer.record("stage.pop", t_pop, r0,
                                      parent=tctx, args=args)
                        tracer.record("reduce", r0, r1, parent=tctx,
                                      args=args)
                        tracer.record("write", w0, w1, parent=tctx,
                                      args=args)
                        spans = tracer.spans()
                        tracer.clear()
                    results.put((
                        "done", snap.step, group,
                        [r.to_json() for r in ctx.records],
                        sorted(outputs), snap.kind, snap.meta,
                        spans, ((r1 - r0) / 1e6, (w1 - w0) / 1e6),
                        drain_events()))
            except BaseException:
                obs_events.EVENTS.emit(obs_events.LANE_ERROR,
                                       step=snap.step, group=group,
                                       stage="reduce")
                results.put(("error", snap.step, group, None, None,
                             traceback.format_exc(), None, None, None,
                             drain_events()))
            finally:
                area.release(snap)
    finally:
        db.close()
        area.detach()
        results.put(("exit", None, group, None, None, None, None, None,
                     dict(lane_stats) if lane_stats else None,
                     drain_events()))


_DAG_CACHE_MAX = 8


def _pooled_lane_main(task_q, sync, results) -> None:
    """Resident pooled lane: serve staging-attach jobs until poisoned.

    Spawn+import cost is paid once; each task re-runs :func:`_lane_main`
    against a fresh shared-memory area rebuilt from a primitive-free
    spec plus the sync objects this process inherited at spawn
    (``ShmStagingArea.handle_from_spec``). ``None`` ends the lane.

    Tasks name their reducer config by :func:`reducer_fingerprint`; the
    rebuilt :class:`ReducerDAG` is cached here keyed by that fingerprint
    so repeat borrows with the same config skip the unpickle+rebuild
    entirely — the borrower then sends ``reducers=None``. Cache hits and
    rebuilds ride home in the "exit" message (cumulative over this
    lane's lifetime) and surface as ``insitu_lane_dag_*`` metrics.
    """
    dag_cache: dict[str, ReducerDAG] = {}
    stats = {"jobs": 0, "dag_rebuilds": 0, "dag_cache_hits": 0}
    while True:
        task = task_q.get()
        if task is None:
            return
        spec, root, group, fp, reducers, compress, durable_parts = task
        dag = dag_cache.get(fp)
        if dag is None:
            if reducers is None:
                # borrower believed we had this config cached but we
                # don't (fresh lane in a recycled entry): fail the job
                # loudly and report the per-job exit the collector awaits
                results.put(("error", -1, group, None, None,
                             f"pooled lane has no cached DAG for "
                             f"fingerprint {fp} and got no reducers",
                             None, None, None, None))
                results.put(("exit", None, group, None, None, None, None,
                             None, dict(stats), None))
                continue
            while len(dag_cache) >= _DAG_CACHE_MAX:   # bound residency
                dag_cache.pop(next(iter(dag_cache)))
            dag = dag_cache[fp] = ReducerDAG(reducers)
            stats["dag_rebuilds"] += 1
        else:
            stats["dag_cache_hits"] += 1
        stats["jobs"] += 1
        handle = ShmStagingArea.handle_from_spec(spec, sync)
        _lane_main(handle, root, group, dag, compress, durable_parts,
                   results, lane_stats=stats)


class _PooledLane:
    """One resident lane process plus its spawn-inherited plumbing."""

    def __init__(self, ctx, results, index: int):
        self.task_q = ctx.Queue()
        lock = ctx.Lock()
        self.sync = (lock, _CrashSafeCondition(lock, ctx),
                     _CrashSafeCondition(lock, ctx))
        self.proc = ctx.Process(target=_pooled_lane_main,
                                args=(self.task_q, self.sync, results),
                                name=f"insitu-pool-lane{index}",
                                daemon=True)


class _PoolEntry:
    """A reusable set of ``n`` lanes sharing one results queue."""

    def __init__(self, n: int):
        self.ctx = multiprocessing.get_context("spawn")
        self.results = self.ctx.Queue()
        self.lanes = [_PooledLane(self.ctx, self.results, i)
                      for i in range(n)]
        #: reducer fingerprints every lane of this entry has cached
        #: (lanes receive the same configs in lockstep at borrow time)
        self.known_fps: set[str] = set()
        for lane in self.lanes:
            lane.proc.start()

    def alive(self) -> bool:
        return all(lane.proc.is_alive() for lane in self.lanes)

    def terminate(self) -> None:
        for lane in self.lanes:
            lane.task_q.put(None)
        for lane in self.lanes:
            lane.proc.join(timeout=5.0)
            if lane.proc.is_alive():
                lane.proc.terminate()
                lane.proc.join(timeout=5.0)
        self.results.close()
        self.results.join_thread()


class LanePool:
    """Module-level pool of resident process lanes, keyed by group count.

    ``InTransitEngine(backend="process", lane_pool=True)`` borrows a
    matching entry (spawning one on first use) and returns it at
    ``close()``, so short-lived pipelines stop paying the ~1-2 s
    spawn+import per lane per engine. Lanes that failed to drain (or
    died) are discarded, never re-pooled. Call :func:`shutdown_pool`
    (or ``LANE_POOL.shutdown()``) to reclaim the resident processes.
    """

    def __init__(self):
        self._free: dict[int, list[_PoolEntry]] = {}
        self._lock = threading.Lock()
        #: borrow/spawn/release accounting (surfaced through
        #: ``ProcessLaneBackend.telemetry`` as insitu_lane_pool_*)
        self.stats = {"borrows": 0, "spawns": 0, "releases": 0,
                      "discards": 0}

    def acquire(self, n: int) -> _PoolEntry:
        dead: list[_PoolEntry] = []
        try:
            with self._lock:
                self.stats["borrows"] += 1
                entries = self._free.get(n, [])
                while entries:
                    entry = entries.pop()
                    if entry.alive():
                        return entry
                    dead.append(entry)   # a lane died while parked
                    self.stats["discards"] += 1
                self.stats["spawns"] += 1
            return _PoolEntry(n)
        finally:
            for entry in dead:           # joins run outside the lock
                entry.terminate()

    def release(self, entry: _PoolEntry) -> None:
        if not entry.alive():
            with self._lock:
                self.stats["discards"] += 1
            entry.terminate()
            return
        with self._lock:
            self.stats["releases"] += 1
            self._free.setdefault(len(entry.lanes), []).append(entry)

    def telemetry(self) -> dict:
        with self._lock:
            parked = sum(len(v) for v in self._free.values())
            return {**self.stats, "parked_entries": parked}

    def shutdown(self) -> None:
        """Terminate every parked lane (borrowed entries die with their
        engine's ``close``-time discard)."""
        with self._lock:
            entries = [e for lst in self._free.values() for e in lst]
            self._free.clear()
        for entry in entries:
            entry.terminate()


#: the process-lane pool (ISSUE 5: amortize lane spawn across engines)
LANE_POOL = LanePool()


def shutdown_pool() -> None:
    """Reclaim every parked pooled lane process."""
    LANE_POOL.shutdown()


class ProcessLaneBackend(LaneBackend):
    """One spawned OS process per contributor group over shm staging.

    The live-pipeline version of the paper's claim: every contributor
    writes its own domain with no shared interpreter lock. Each lane
    owns its group files exclusively, which requires one Hercule group
    per domain — the engine creates its database with ``ncf=1`` for
    this backend (and refuses a database where lanes would share a
    group file).

    Crash semantics: a lane dying mid-part leaves at most orphaned
    bytes in its own group file — the step's manifest never references
    them. The death is surfaced as an engine error on the next
    ``check_errors``; steps whose parts were queued to the dead lane
    finalize through the engine's step TTL (if enabled) with the
    surviving domains.
    """

    name = "process"

    def __init__(self, engine, *, workers: int, queue_capacity: int,
                 policy: str, lane_pool: bool = False):
        super().__init__(engine)
        db = engine.db
        if engine.n_domains > 1 and db.ncf != 1:
            raise ValueError(
                f"backend='process' needs one Hercule group per domain so "
                f"each lane owns its files; database has ncf={db.ncf} "
                f"(create the engine with ncf=1)")
        self._pooled = bool(lane_pool)
        self._entry = None
        if self._pooled:
            # borrow resident lanes; their sync primitives were
            # inherited at spawn, so the fresh staging areas adopt them
            self._entry = LANE_POOL.acquire(engine.n_domains)
            ctx = self._entry.ctx
            self.stages = [
                ShmStagingArea(capacity=queue_capacity, policy=policy,
                               n_slots=queue_capacity + 2,
                               on_evict=engine._on_evict, mp_context=ctx,
                               sync=lane.sync)
                for lane in self._entry.lanes]
            self._results = self._entry.results
            self._procs = [lane.proc for lane in self._entry.lanes]
        else:
            ctx = multiprocessing.get_context("spawn")
            self.stages = [
                ShmStagingArea(capacity=queue_capacity, policy=policy,
                               n_slots=queue_capacity + 2,
                               on_evict=engine._on_evict, mp_context=ctx)
                for _ in range(engine.n_domains)]
            self._results = ctx.Queue()
            reducers = list(engine.dag)
            self._procs = [
                ctx.Process(target=_lane_main,
                            args=(area.handle(), db.root, g, reducers,
                                  engine.compress, engine.durable_parts,
                                  self._results),
                            name=f"insitu-lane-g{g}", daemon=True)
                for g, area in enumerate(self.stages)]
        self._mp = ctx
        self._collector = threading.Thread(
            target=self._collect, name="insitu-collector", daemon=True)
        self._stopping = False
        self._exited: set[int] = set()
        #: lifetime DAG-cache accounting reported by pooled lanes in
        #: their "exit" messages, summed over this backend's lanes
        self.lane_stats = {"jobs": 0, "dag_rebuilds": 0,
                           "dag_cache_hits": 0}

    def start(self) -> None:
        if self._pooled:
            engine = self.engine
            reducers = list(engine.dag)
            # satellite fix: don't re-pickle the reducers on every
            # borrow — name the config by fingerprint and send the
            # payload only when the entry's lanes haven't cached it
            fp = reducer_fingerprint(reducers)
            payload = None if fp in self._entry.known_fps else reducers
            for g, (lane, area) in enumerate(zip(self._entry.lanes,
                                                 self.stages)):
                lane.task_q.put((area.spec(), engine.db.root, g, fp,
                                 payload, engine.compress,
                                 engine.durable_parts))
            self._entry.known_fps.add(fp)
        else:
            for p in self._procs:
                p.start()
        self._collector.start()

    # ------------------------------------------------------- result intake
    def _collect(self) -> None:
        eng = self.engine
        while True:
            try:
                msg = self._results.get(timeout=0.25)
            except (ValueError, OSError):
                return   # results queue torn down under a stuck stop
            except queue.Empty:
                eng._run_deferred()
                eng._sweep_ttl()
                if len(self._exited) == len(self._procs) or \
                        (self._stopping and
                         not any(p.is_alive() for p in self._procs)):
                    return
                if not self._stopping:
                    self._check_lanes()
                continue
            tag, step, group = msg[0], msg[1], msg[2]
            if len(msg) > 9 and msg[9]:
                self._relay_events(group, msg[9])
            if tag == "exit":
                self._exited.add(group)
                if msg[8]:               # pooled lane lifetime stats
                    for k, v in msg[8].items():
                        self.lane_stats[k] = \
                            self.lane_stats.get(k, 0) + v
                if len(self._exited) == len(self._procs):
                    eng._run_deferred()
                    return
            elif tag == "done":
                recs, reducers, kind, meta, spans, timings = msg[3:9]
                if spans:                # lane spans join the parent trace
                    TRACER.ingest(spans)
                if timings is not None and obs_metrics.ENABLED:
                    eng._h_reduce.labels(group).observe(timings[0])
                    eng._h_write.labels(group).observe(timings[1])
                eng._part_records(step, group,
                                  [Record.from_json(r) for r in recs],
                                  set(reducers), kind, meta)
            elif tag == "skipped":
                with eng._wlock:
                    eng._skipped += 1
                eng._part_done(step, None, None)
            elif tag == "error":
                eng._errors.append(RuntimeError(
                    f"process lane g{group} failed at step {step}:\n"
                    f"{msg[5]}"))
                with eng._wlock:
                    eng._failed += 1
                if step < 0:
                    # fatal transport failure: the lane is exiting; stop
                    # producers from queueing (or blocking) behind it
                    self.stages[group].close()
                else:
                    eng._part_done(step, None, None)
            eng._run_deferred()

    def _relay_events(self, group: int, evs: list) -> None:
        """Land a lane's flight-recorder drain: into its own ledger
        domain when a run ledger is bound, else into the engine-process
        ring so the events at least stay live-visible."""
        led = self.engine.ledger
        if led is not None:
            from ..obs.ledger import lane_domain
            led.ingest_domain(lane_domain(group), {"events": evs})
        else:
            obs_events.EVENTS.ingest(evs)

    def _check_lanes(self) -> None:
        """Surface lanes that died without reporting (crash semantics).

        A clean exit announces itself on the results queue; only a
        nonzero exit code is a crash (a zero-exit lane may simply have
        its "exit" message still queued).
        """
        for g, p in enumerate(self._procs):
            if g not in self._exited and p.exitcode not in (None, 0):
                self._exited.add(g)
                self.engine._errors.append(RuntimeError(
                    f"process lane g{g} died (exit code {p.exitcode}) "
                    f"without draining its staging area"))
                # fail fast instead of deadlocking a block-policy
                # producer against a lane that will never pop again
                self.stages[g].close()
                # flight recorder: a SIGKILLed lane reports nothing, so
                # the engine writes the crash event on its behalf and
                # forces a durable ledger flush with whatever partial
                # attribution the dead lane's steps have
                obs_events.EVENTS.emit(
                    obs_events.LANE_CRASH, group=g,
                    exitcode=p.exitcode)
                obs_events.EVENTS.dump("lane.crash", group=g,
                                       exitcode=p.exitcode)

    def telemetry(self) -> dict:
        out = {"kind": "process", "pooled": self._pooled,
               "n_lanes": len(self._procs),
               "lanes_exited": len(self._exited), **self.lane_stats}
        if self._pooled:
            out.update({f"pool_{k}": v
                        for k, v in LANE_POOL.telemetry().items()})
        return out

    # ------------------------------------------------------------ control
    def pre_finalize(self, pend) -> None:
        # lanes flushed their appends to the page cache; make exactly
        # the files this manifest references durable before the commit
        if pend.ctx is not None and pend.ctx.records:
            self.engine.db.fsync_files(r.file for r in pend.ctx.records)

    def stop(self, timeout: float = 30.0) -> None:
        for area in self.stages:
            area.close()
        if self._pooled:
            self._stop_pooled(timeout)
            return
        killed = []
        for p in self._procs:
            if p.pid is None:            # never-started lane
                continue
            p.join(timeout=timeout)
            if p.is_alive():
                # a stuck lane is its own process: killing it cannot
                # corrupt the parent; its un-reported bytes stay
                # orphaned (no manifest references them)
                p.terminate()
                p.join(timeout=5.0)
                killed.append(p.name)
        self._stopping = True
        if self._collector.ident is not None:
            self._collector.join(timeout=timeout)
        for area in self.stages:
            area.unlink()
        self._results.close()
        self._results.join_thread()
        if killed:
            self.engine._errors.append(TimeoutError(
                f"process lanes {killed} did not stop; terminated "
                f"(unreported parts lost)"))

    def _stop_pooled(self, timeout: float) -> None:
        """Wind down borrowed pooled lanes: wait for their per-job 'exit'
        reports (the lane process itself stays alive), then return the
        entry to the pool — or discard it if anything looks wrong."""
        clean = True
        if self._collector.ident is not None:
            self._collector.join(timeout=timeout)
            clean = (not self._collector.is_alive()
                     and len(self._exited) == len(self._procs))
        self._stopping = True
        for area in self.stages:
            area.unlink()
        if clean and self._entry.alive():
            LANE_POOL.release(self._entry)
        else:
            self._entry.terminate()
            self.engine._errors.append(TimeoutError(
                "pooled process lanes did not finish their jobs; "
                "lanes discarded (unreported parts lost)"))


register_backend("thread", ThreadLaneBackend)
register_backend("process", ProcessLaneBackend)
