"""Logical-axis sharding rules (MaxText-style) for all parallelism forms.

Model code annotates tensors with *logical* axis names; a rules table maps
them to mesh axes. Resolution is shape-aware: a logical->mesh mapping is
dropped (replicated) when the dimension is not divisible by the mesh axes'
product — e.g. 8 KV heads on a 16-way 'model' axis fall back to replicated
KV (correct GQA TP semantics), without per-arch special cases.

The rules table is the primary §Perf hillclimb lever (DESIGN.md §4).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rules: tuple values are tried jointly (a dim can shard over
# several mesh axes); None = replicated.
DEFAULT_RULES: dict[str, tuple | str | None] = {
    "batch": ("pod", "data"),      # data parallel (pod folds into DP)
    "seq": None,                   # sequence (sharded for SP via override)
    "kv_seq": None,                # decode KV-cache sequence axis
    "embed": None,                 # activation d_model (i6b tried 'data'
                                   # for table ZeRO: memory term regressed
                                   # 132->197 s from d-gathers at lookup)
    "heads": "model",              # tensor parallel attention
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",                # tensor parallel FFN
    "vocab": "model",              # tensor parallel embedding / logits
    "experts": "model",            # expert parallel (block-diagonal)
    "expert_cap": "data",          # expert capacity rides the data axis
    "expert_in": "data",           # expert weight d_model dim (ZeRO)
    "expert_mlp": "model",         # TP inside experts (when E % model != 0)
    "fsdp": "data",                # ZeRO-3 param dim
    "state": "model",              # SSM / LRU state width
    "frames": None,                # encoder stub frames
    "patches": None,
}

_CTX = threading.local()


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def resolve_spec(shape: tuple, axes: tuple, rules: dict, mesh: Mesh) -> P:
    """PartitionSpec for ``shape`` with logical ``axes`` under ``rules``."""
    assert len(shape) == len(axes), f"{shape} vs {axes}"
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, axes):
        if name is None:
            parts.append(None)
            continue
        cand = rules.get(name)
        if cand is None:
            parts.append(None)
            continue
        cand = (cand,) if isinstance(cand, str) else tuple(cand)
        cand = [a for a in cand if a in mesh.shape and a not in used]
        # largest prefix whose product divides the dim
        chosen = []
        prod = 1
        for a in cand:
            if dim % (prod * _axis_size(mesh, a)) == 0:
                chosen.append(a)
                prod *= _axis_size(mesh, a)
        used.update(chosen)
        parts.append(tuple(chosen) if len(chosen) > 1 else (chosen[0] if chosen else None))
    return P(*parts)


def named_sharding(shape: tuple, axes: tuple, rules: dict, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, axes, rules, mesh))


@contextlib.contextmanager
def use_rules(rules: dict, mesh: Mesh):
    """Activate rules+mesh for :func:`constrain` during tracing."""
    prev = getattr(_CTX, "val", None)
    _CTX.val = (dict(rules), mesh)
    try:
        yield
    finally:
        _CTX.val = prev


def active() -> tuple[dict, Mesh] | None:
    return getattr(_CTX, "val", None)


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside use_rules."""
    ctx = active()
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = resolve_spec(x.shape, tuple(axes), rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(shapes_tree, axes_tree, rules: dict, mesh: Mesh):
    """Map matching (shapes, axes) pytrees to NamedShardings."""
    # shapes_tree leaves are ShapeDtypeStructs/arrays; flatten_up_to hands the
    # corresponding axes tuple over whole.
    return jax.tree.map(
        lambda s, a: named_sharding(tuple(s.shape), tuple(a), rules, mesh),
        shapes_tree, axes_tree)


def merge_rules(*overrides) -> dict:
    out = dict(DEFAULT_RULES)
    for o in overrides:
        if o:
            out.update(o)
    return out
