"""Deterministic synthetic token pipeline, shardable by host.

Tokens are a pure function of (seed, step, batch row, position) via a
counter-based hash, so (a) any host can produce exactly its shard without
coordination, and (b) restart-at-step-k reproduces the same stream —
which is what makes the crash/restart integration test bitwise exact.
A Zipf-ish transform skews the id distribution so losses move like real
text rather than uniform noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf: float = 1.1


def _hash64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer, vectorized."""
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # zipf-ish CDF over vocab for realistic id frequencies
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        w = 1.0 / ranks ** cfg.zipf
        self.cdf = np.cumsum(w) / w.sum()

    def batch(self, step: int, *, host_index: int = 0, host_count: int = 1):
        """Return this host's shard {'tokens','labels'} for ``step``."""
        cfg = self.cfg
        rows = cfg.global_batch // host_count
        row0 = host_index * rows
        b_idx = (np.arange(rows, dtype=np.uint64) + np.uint64(row0))[:, None]
        s_idx = np.arange(cfg.seq_len + 1, dtype=np.uint64)[None, :]
        key = (np.uint64(cfg.seed) * np.uint64(0x1000003)
               + np.uint64(step) * np.uint64(0x85EBCA77))
        h = _hash64(key + b_idx * np.uint64(1_000_003) + s_idx)
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        toks = np.searchsorted(self.cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, cfg.vocab_size - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
